//! Summary statistics used by experiments, benches, and tests.

/// Running mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation of a slice.
pub fn std(xs: &[f32]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let ss: f64 = xs.iter().map(|&x| (x as f64 - m).powi(2)).sum();
    (ss / (xs.len() - 1) as f64).sqrt()
}

/// Quantile by linear interpolation on a sorted copy. q in [0, 1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
    }
}

/// Pearson correlation of two equal-length slices.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (&x, &y) in a.iter().zip(b.iter()) {
        cov += (x - ma) * (y - mb);
        va += (x - ma).powi(2);
        vb += (y - mb).powi(2);
    }
    if va == 0.0 || vb == 0.0 {
        return 0.0;
    }
    cov / (va.sqrt() * vb.sqrt())
}

/// Ordinary least squares fit y = a + b·x; returns (a, b).
pub fn linfit(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2);
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    for (&xi, &yi) in x.iter().zip(y.iter()) {
        sxx += (xi - mx).powi(2);
        sxy += (xi - mx) * (yi - my);
    }
    let b = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    (my - b * mx, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert_eq!(r.count(), 5);
        assert!((r.mean() - 3.0).abs() < 1e-12);
        assert!((r.var() - 2.5).abs() < 1e-12);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 5.0);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect() {
        let x = [1.0, 2.0, 3.0];
        let y = [2.0, 4.0, 6.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yn = [6.0, 4.0, 2.0];
        assert!((pearson(&x, &yn) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn linfit_recovers_line() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 - 0.5 * v).collect();
        let (a, b) = linfit(&x, &y);
        assert!((a - 3.0).abs() < 1e-10);
        assert!((b + 0.5).abs() < 1e-10);
    }

    #[test]
    fn slice_stats() {
        let xs = [2.0f32, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-9);
        assert!((std(&xs) - 2.138089935).abs() < 1e-6);
    }
}
