//! Minimal JSON parser / serializer.
//!
//! The offline vendor set has no `serde`, so the config system
//! (`config::loader`), checkpoints, and the artifact manifest parser are
//! built on this hand-rolled implementation. Supports the full JSON value
//! model with f64 numbers; serialization is deterministic (object keys keep
//! insertion order).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    it.write(out, indent, depth + 1);
                }
                if indent.is_some() && !items.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !map.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }

    // ---- typed accessors (used heavily by config::loader) ----

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// `get` then `as_f64` with default.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Json::as_bool).unwrap_or(default)
    }
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }

    /// Convenience constructors.
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }
    /// Parse an array of numbers back into f32s.
    pub fn to_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()?.iter().map(|j| j.as_f64().map(|n| n as f32)).collect()
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", lit)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // advance one UTF-8 char
                    let start = self.pos;
                    let mut end = start + 1;
                    while end < self.bytes.len() && (self.bytes[end] & 0xC0) == 0x80 {
                        end += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"device":{"dw_min":0.001,"kind":"SoftBounds"},"tiles":[1,2,3],"use_gpu":false}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo δ\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo δ");
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""é""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é");
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nulla").is_err());
    }

    #[test]
    fn accessors_defaults() {
        let j = Json::parse(r#"{"x": 2.5, "flag": true, "name": "t"}"#).unwrap();
        assert_eq!(j.f64_or("x", 0.0), 2.5);
        assert_eq!(j.f64_or("missing", 7.0), 7.0);
        assert!(j.bool_or("flag", false));
        assert_eq!(j.str_or("name", ""), "t");
        assert_eq!(j.get("x").unwrap().as_usize(), None); // 2.5 not integral
    }

    #[test]
    fn f32_vec_roundtrip() {
        let v = vec![1.0f32, -0.5, 3.25];
        let j = Json::arr_f32(&v);
        assert_eq!(Json::parse(&j.to_string()).unwrap().to_f32_vec().unwrap(), v);
    }
}
