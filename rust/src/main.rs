//! aihwsim CLI launcher.
//!
//! Subcommands:
//!   train        — train an MLP/LeNet on synthetic data (analog or FP)
//!   infer-drift  — hardware-aware accuracy-over-time evaluation
//!   response     — device pulse-response traces (Fig. 3B)
//!   drift        — PCM conductance drift traces (Fig. 3C)
//!   e2e          — runtime-backed (AOT/PJRT) hardware-aware training
//!   serve-bench  — concurrent-serving benchmark (micro-batching queue)
//!   fault-sweep  — accuracy-vs-fault-rate robustness grid (defect maps)
//!   sweep        — design-space sweep: bit-slicing × ADC bits × fault
//!                  rates × t_inference, all cells in one parallel grid
//!   presets      — list device presets
//!
//! Common options: `--config <file.json>` loads an RPUConfig (see
//! `config::loader` for the schema); `--csv <path>` writes metrics;
//! `--threads N` pins the worker-thread count (same effect as the
//! `AIHWSIM_THREADS` env var, which it overrides); `--kernel-backend
//! auto|scalar|tiled|simd` forces the MVM kernel backend for the whole
//! process (same effect as `AIHWSIM_BACKEND`, which it overrides).

use aihwsim::config::{loader, presets, AdcParameters, AdcRange, ForwardBackend, RPUConfig};
use aihwsim::coordinator::checkpoint::{collect_grid_layers, collect_linear_layers};
use aihwsim::coordinator::evaluator::{
    accuracy_over_time, design_sweep_uncached, design_sweep_with_observer, fault_sweep,
    mlp_from_layers, repeat_seed, sweep_grid, DriftEvalConfig, SweepRow,
};
use aihwsim::faults::{FaultModel, FaultStats};
use aihwsim::nn::AnalogLinear;
use aihwsim::coordinator::experiments;
#[cfg(feature = "pjrt")]
use aihwsim::coordinator::hwa_pipeline::HwaPipeline;
use aihwsim::coordinator::trainer;
use aihwsim::data::synthetic_images;
use aihwsim::nn::sequential::{lenet, mlp, Backend};
use aihwsim::nn::Module;
use aihwsim::serve::{MicroBatcher, ServeOptions};
#[cfg(feature = "pjrt")]
use aihwsim::runtime::Runtime;
use aihwsim::util::argparse::Args;
use aihwsim::util::json::Json;
use aihwsim::util::logging::{info, CsvLogger};
use aihwsim::util::rng::Rng;

fn usage() -> ! {
    eprintln!(
        "usage: aihwsim <command> [options]\n\
         commands:\n\
           train        --backend analog|fp --arch mlp|lenet --preset <name> \\\n\
                        --epochs N --batch N --lr F --samples N --csv path --config file.json \\\n\
                        --max-in N --max-out N (tile-grid mapping limits, 0 = unlimited) \\\n\
                        --save path (dense ckpt) --save-grid path (per-shard ckpt) \\\n\
                        --t-inference s1,s2,... (post-training PCM drift evaluation)\n\
           infer-drift  --epochs N --gdc true|false --t-inference s1,s2,... --n-reps N \\\n\
                        --config file.json (inference options) --csv path\n\
           response     --preset <name> --pulses N --devices N --csv path\n\
           drift        --csv path\n\
           e2e          --steps N --lr F --artifact hwa_train_step|fp_train_step\n\
           serve-bench  --dims d0,d1,... --clients 1,4,8,16 --windows-us 0,100,1000 \\\n\
                        --max-batch N --requests-per-client N --out BENCH_serving.json \\\n\
                        --config file.json (training + inference + serving sections)\n\
           fault-sweep  --dims d0,d1,... --rates r1,r2,... --t-inference s1,s2,... \\\n\
                        --n-reps N --epochs N --out BENCH_faults.json \\\n\
                        --config file.json (training + inference sections)\n\
           sweep        --dims d0,d1,... --slices 1,2,4 --adc-bits 0,6,8 \\\n\
                        --adc-range auto_max|per_column|fixed --adc-fixed-range F \\\n\
                        --rates 0.0,0.01 --t-inference s1,s2,... --n-reps N \\\n\
                        --epochs N --out BENCH_sweeps.json --csv path (rows\n\
                        stream as cells complete) --bench-uncached (also time\n\
                        the per-point engine and report the snapshot speedup) \\\n\
                        --config file.json (training + inference sections)\n\
           presets\n\
         common: --threads N (pin worker threads; overrides AIHWSIM_THREADS)\n\
                 --kernel-backend auto|scalar|tiled|simd (force the MVM kernel\n\
                 backend process-wide; overrides AIHWSIM_BACKEND and any\n\
                 per-config forward.backend setting)"
    );
    std::process::exit(2);
}

/// `--threads N` pins the worker-thread count for this process by setting
/// `AIHWSIM_THREADS` before any parallel region runs (the threadpool
/// re-reads the variable on every fan-out, but setting it up front keeps
/// one process at one setting).
fn apply_thread_override(args: &Args) {
    if let Some(v) = args.get("threads") {
        match v.parse::<usize>() {
            Ok(n) if n >= 1 => std::env::set_var("AIHWSIM_THREADS", n.to_string()),
            _ => {
                eprintln!("--threads: expected a positive integer, got '{v}'");
                std::process::exit(2);
            }
        }
    }
}

/// `--kernel-backend NAME` forces the MVM kernel backend for this process
/// by setting `AIHWSIM_BACKEND` (re-read on every `backend::resolve`, so
/// it overrides both the Auto default and any `forward.backend` config
/// key). `--backend NAME` is also honored when its value names a kernel
/// backend — `train` already uses `--backend analog|fp` for the tile
/// substrate, and the two value sets are disjoint, so there is no
/// ambiguity.
fn apply_backend_override(args: &Args) {
    if let Some(v) = args.get("kernel-backend") {
        match ForwardBackend::parse(v) {
            Some(b) => std::env::set_var("AIHWSIM_BACKEND", b.as_str()),
            None => {
                eprintln!("--kernel-backend: expected auto|scalar|tiled|simd, got '{v}'");
                std::process::exit(2);
            }
        }
    } else if let Some(b) = args.get("backend").and_then(|v| ForwardBackend::parse(v)) {
        std::env::set_var("AIHWSIM_BACKEND", b.as_str());
    }
}

/// Parse a comma-separated usize list option, exiting on malformed input.
fn usize_list(args: &Args, key: &str, default: &[usize]) -> Vec<usize> {
    match args.get(key) {
        None => default.to_vec(),
        Some(raw) => raw
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.parse::<usize>().unwrap_or_else(|_| {
                    eprintln!("--{key}: bad number '{s}' in '{raw}'");
                    std::process::exit(2);
                })
            })
            .collect(),
    }
}

/// Parse a `--t-inference` comma list, exiting on malformed input.
fn t_inference_list(args: &Args) -> Option<Vec<f32>> {
    match args.f32_list("t-inference") {
        None => None,
        Some(Ok(v)) if v.is_empty() => {
            eprintln!("--t-inference: empty schedule");
            std::process::exit(2);
        }
        Some(Ok(v)) => Some(v),
        Some(Err(e)) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }
}

/// Load the training `RPUConfig`, returning the parsed `--config` JSON
/// alongside it so combined documents' other sections (e.g. `"inference"`)
/// can be consumed without re-reading the file.
fn load_config(args: &Args) -> (RPUConfig, Option<Json>) {
    let mut json = None;
    let mut cfg = if let Some(path) = args.get("config") {
        let parsed = std::fs::read_to_string(path)
            .map_err(|e| format!("{path}: {e}"))
            .and_then(|text| Json::parse(&text).map_err(|e| format!("{path}: {e}")))
            .and_then(|j| loader::rpu_config_from_json(&j).map(|c| (j, c)));
        match parsed {
            Ok((j, c)) => {
                json = Some(j);
                c
            }
            Err(e) => {
                eprintln!("config error: {e}");
                std::process::exit(2);
            }
        }
    } else {
        let mut cfg = RPUConfig::default();
        if let Some(p) = args.get("preset") {
            match presets::by_name(p) {
                Some(d) => cfg.device = d,
                None => {
                    eprintln!("unknown preset '{p}'");
                    std::process::exit(2);
                }
            }
        }
        cfg
    };
    // CLI tile-grid mapping overrides (layers larger than these limits are
    // split over a TileGrid of shards; 0 = unlimited)
    cfg.mapping.max_input_size = args.usize_or("max-in", cfg.mapping.max_input_size);
    cfg.mapping.max_output_size = args.usize_or("max-out", cfg.mapping.max_output_size);
    (cfg, json)
}

fn cmd_train(args: &Args) {
    let backend = match args.str_or("backend", "analog").as_str() {
        "fp" | "float" => Backend::FloatingPoint,
        _ => Backend::Analog,
    };
    let (cfg, cfg_json) = load_config(args);
    let samples = args.usize_or("samples", 480);
    let side = args.usize_or("side", 16);
    let classes = args.usize_or("classes", 10);
    let seed = args.u64_or("seed", 42);
    let mut rng = Rng::new(seed);
    // one generator call → one prototype set; hold out 20% for testing
    let (train_ds, test_ds) =
        synthetic_images(samples + samples / 4, classes, side, 1, &mut rng).split(samples / 4);
    let mut model = match args.str_or("arch", "mlp").as_str() {
        "lenet" => lenet(1, side, classes, backend, &cfg, &mut rng),
        _ => mlp(&[side * side, 128, 64, classes], backend, &cfg, &mut rng),
    };
    info(&model.summary());
    let tc = trainer::TrainConfig {
        epochs: args.usize_or("epochs", 10),
        batch_size: args.usize_or("batch", 32),
        lr: args.f32_or("lr", 0.1),
        seed,
        log_every: 1,
        csv_path: args.get("csv").map(String::from),
    };
    let report = trainer::train_classifier(&mut model, &train_ds, &test_ds, &tc);
    info(&format!(
        "done: {} steps in {:.1}s ({:.0} samples/s train) — final loss {:.4}, test acc {:.3}",
        report.steps,
        report.wall_s,
        report.samples_per_s,
        report.final_loss(),
        report.final_test_acc()
    ));
    if let Some(path) = args.get("save") {
        // collect every AnalogLinear layer's weights into a checkpoint
        let layers = collect_linear_layers(&mut model);
        match aihwsim::coordinator::checkpoint::save(path, &layers) {
            Ok(()) => info(&format!("saved checkpoint ({} linear layers) to {path}", layers.len())),
            Err(e) => eprintln!("checkpoint save failed: {e}"),
        }
    }
    if let Some(path) = args.get("save-grid") {
        // per-shard grid checkpoint of the *linear* layers (same contract
        // as --save): preserves the physical tile mapping
        let layers = collect_grid_layers(&mut model);
        let shards: usize = layers.iter().map(|l| l.shards.len()).sum();
        match aihwsim::coordinator::checkpoint::save_grids(path, &layers) {
            Ok(()) => info(&format!(
                "saved grid checkpoint ({} linear layers, {shards} shards) to {path}",
                layers.len()
            )),
            Err(e) => eprintln!("grid checkpoint save failed: {e}"),
        }
        if layers.is_empty() {
            eprintln!("warning: --save-grid found no linear layers (conv-only models are not grid-checkpointable yet)");
        }
    }
    if let Some(times) = t_inference_list(args) {
        // post-training inference lifecycle on the *trained* network —
        // works for any architecture (conv included): convert the tile
        // grids in place, program, and sweep the drift schedule. A
        // combined --config file's "inference" section configures the
        // converted tiles (the training keys were consumed above).
        let mut icfg = aihwsim::config::InferenceRPUConfig::default();
        if let Some(json) = &cfg_json {
            if json.get("inference").is_some() {
                match loader::inference_options_from_json(json) {
                    Ok(o) => icfg = o.config,
                    Err(e) => {
                        eprintln!("config error: {e}");
                        std::process::exit(2);
                    }
                }
            }
        }
        if let Some(g) = args.get("gdc") {
            icfg.drift_compensation = g == "true";
        }
        model.convert_to_inference(&icfg, &mut rng);
        let series = accuracy_over_time(&mut model, &test_ds, &times, tc.batch_size);
        for (t, acc) in &series {
            info(&format!("t = {t:>12.0}s  acc {acc:.3}"));
        }
    }
}

fn cmd_infer_drift(args: &Args) {
    let seed = args.u64_or("seed", 42);
    let mut rng = Rng::new(seed);
    let side = 16;
    let classes = 10;
    let train_ds = synthetic_images(480, classes, side, 1, &mut rng);
    // inference options: --config file first, then CLI overrides
    let mut opts = match args.get("config") {
        Some(path) => match loader::load_inference_options(path) {
            Ok(o) => o,
            Err(e) => {
                eprintln!("config error: {e}");
                std::process::exit(2);
            }
        },
        None => loader::InferenceOptions::default(),
    };
    if let Some(times) = t_inference_list(args) {
        opts.t_inference = times;
    }
    opts.n_repeats = args.usize_or("n-reps", opts.n_repeats);
    if let Some(g) = args.get("gdc") {
        opts.config.drift_compensation = g == "true";
    }
    let gdc = opts.config.drift_compensation;
    // HWA-train + (time × repeat) drift sweep on the generic engine
    let params = experiments::InferenceDriftParams {
        dims: vec![side * side, 128, classes],
        epochs: args.usize_or("epochs", 12),
        w_noise: args.f32_or("w-noise", 0.06),
        icfg: opts.config.clone(),
        eval: DriftEvalConfig {
            times: opts.t_inference.clone(),
            n_repeats: opts.n_repeats,
            batch: 32,
            seed,
        },
    };
    let (rep, drift) = experiments::inference_drift_experiment(&train_ds, &params);
    info(&format!("HWA-trained: acc {:.3}", rep.final_test_acc()));
    let mut csv = args.get("csv").map(|p| {
        CsvLogger::create(p, &["t_seconds", "acc_mean", "acc_std", "gdc", "g_mean_us"]).unwrap()
    });
    for p in &drift.points {
        let g_mean = p.layer_conductance.first().map(|c| c.0).unwrap_or(0.0);
        info(&format!(
            "t = {t:>12.0}s  acc {m:.3} ± {s:.3}  (gdc={gdc}, n={n}, layer-0 g {g_mean:.1} µS)",
            t = p.t,
            m = p.acc_mean,
            s = p.acc_std,
            n = p.acc.len(),
        ));
        if let Some(c) = csv.as_mut() {
            c.row(&[p.t as f64, p.acc_mean, p.acc_std, gdc as u8 as f64, g_mean]).unwrap();
        }
    }
}

fn cmd_response(args: &Args) {
    let preset = args.str_or("preset", "reram_es");
    let pulses = args.usize_or("pulses", 1000);
    let devices = args.usize_or("devices", 64);
    let tr = experiments::device_response(&preset, devices, pulses, args.u64_or("seed", 1));
    info(&format!("preset {} over {} devices, {}↑/{}↓ pulses", preset, devices, pulses, pulses));
    if let Some(p) = args.get("csv") {
        let mut csv = CsvLogger::create(p, &["pulse", "mean", "std", "ideal"]).unwrap();
        for i in 0..tr.pulse.len() {
            csv.row(&[tr.pulse[i] as f64, tr.mean[i], tr.std[i], tr.ideal[i]]).unwrap();
        }
        info(&format!("wrote {p}"));
    } else {
        for i in (0..tr.pulse.len()).step_by((tr.pulse.len() / 20).max(1)) {
            info(&format!(
                "pulse {:4}  mean {:+.3} ± {:.3}  ideal {:+.3}",
                tr.pulse[i], tr.mean[i], tr.std[i], tr.ideal[i]
            ));
        }
    }
}

fn cmd_drift(args: &Args) {
    let times: Vec<f32> = (0..25).map(|i| 25.0 * 10f32.powf(i as f32 * 0.25)).collect();
    let tr = experiments::pcm_drift(&[22.5, 15.0, 7.5, 2.5], &times, 2000, args.u64_or("seed", 1));
    if let Some(p) = args.get("csv") {
        let mut csv =
            CsvLogger::create(p, &["t_seconds", "target_us", "mean_us", "std_us"]).unwrap();
        for (g, means, stds) in &tr.levels {
            for (i, &t) in tr.times.iter().enumerate() {
                csv.row(&[t as f64, *g as f64, means[i], stds[i]]).unwrap();
            }
        }
        info(&format!("wrote {p}"));
    } else {
        for (g, means, stds) in &tr.levels {
            info(&format!(
                "target {g:>5.1} µS: t0 {:.2}±{:.2} → 1y {:.2}±{:.2} µS",
                means[0],
                stds[0],
                means.last().unwrap(),
                stds.last().unwrap()
            ));
        }
    }
}

#[cfg(not(feature = "pjrt"))]
fn cmd_e2e(_args: &Args) {
    eprintln!("e2e requires the `pjrt` feature (cargo build --features pjrt, with the xla/anyhow crates vendored)");
    std::process::exit(2);
}

#[cfg(feature = "pjrt")]
fn cmd_e2e(args: &Args) {
    let dir = Runtime::default_dir();
    let mut pipe = match HwaPipeline::new(&dir, args.u64_or("seed", 42)) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("runtime error: {e:#} (run `make artifacts` first)");
            std::process::exit(1);
        }
    };
    info(&format!("PJRT platform: {}", pipe.platform()));
    let mut rng = Rng::new(7);
    let ds = synthetic_images(args.usize_or("samples", 1024), 10, 28, 1, &mut rng);
    let artifact = args.str_or("artifact", "hwa_train_step");
    let steps = args.usize_or("steps", 100);
    let rep = pipe
        .train(&artifact, &ds, steps, args.f32_or("lr", 0.1), args.usize_or("log-every", 10))
        .expect("training failed");
    let acc = pipe.evaluate(&ds).expect("eval failed");
    info(&format!(
        "{artifact}: {} steps in {:.1}s ({:.1} ms/step, {:.0}% in PJRT), loss {:.3}→{:.3}, acc {acc:.3}",
        rep.steps,
        rep.wall_s,
        1e3 * rep.wall_s / rep.steps as f64,
        100.0 * rep.exec_s / rep.wall_s,
        rep.step_loss.first().unwrap_or(&f32::NAN),
        rep.step_loss.last().unwrap_or(&f32::NAN),
    ));
}

/// One serving-grid cell: `clients` closed-loop threads × `rpc` requests
/// each against a fresh [`MicroBatcher`]. Returns
/// `(requests/s, p50 latency ms, p99 latency ms)`.
fn serve_cell(
    net: &dyn Module,
    clients: usize,
    window_us: u64,
    max_batch: usize,
    rpc: usize,
    in_features: usize,
    seed: u64,
) -> (f64, f64, f64) {
    let opts = ServeOptions {
        batch_window_us: window_us,
        max_batch,
        queue_depth: (4 * max_batch).max(64),
        request_timeout_us: 0,
    };
    let batcher = MicroBatcher::new(net, opts).unwrap_or_else(|e| {
        eprintln!("serve-bench: {e}");
        std::process::exit(2);
    });
    let t0 = std::time::Instant::now();
    let mut lats: Vec<f64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|t| {
                let batcher = &batcher;
                s.spawn(move || {
                    // one deterministic session stream per client; one
                    // split per request
                    let mut session =
                        Rng::new(seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(t as u64 + 1));
                    let mut lat = Vec::with_capacity(rpc);
                    for k in 0..rpc {
                        let x: Vec<f32> = (0..in_features)
                            .map(|j| ((((t * rpc + k) * in_features + j) as f32) * 0.013).sin())
                            .collect();
                        let req_rng = session.split();
                        let t1 = std::time::Instant::now();
                        let y = batcher
                            .submit(x, req_rng)
                            .expect("serve-bench: healthy request failed");
                        lat.push(t1.elapsed().as_secs_f64() * 1e3);
                        std::hint::black_box(y);
                    }
                    lat
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| lats[((lats.len() - 1) as f64 * p).round() as usize];
    ((clients * rpc) as f64 / wall, pct(0.50), pct(0.99))
}

/// Closed-loop concurrent-serving benchmark over a converted (programmed)
/// analog MLP: clients × batch-window grid, with a serial (`max_batch` 1)
/// reference row per client count. Emits `BENCH_serving.json`.
fn cmd_serve_bench(args: &Args) {
    let seed = args.u64_or("seed", 42);
    let (cfg, cfg_json) = load_config(args);
    let dims = usize_list(args, "dims", &[64, 128, 32]);
    if dims.len() < 2 || dims.iter().any(|&d| d == 0) {
        eprintln!("--dims: need at least two positive layer sizes");
        std::process::exit(2);
    }
    let clients = usize_list(args, "clients", &[1, 4, 8, 16]);
    let windows: Vec<u64> =
        usize_list(args, "windows-us", &[0, 100, 1000]).into_iter().map(|w| w as u64).collect();
    let max_batch = args.usize_or("max-batch", 32);
    let rpc = args.usize_or("requests-per-client", 64);
    let out = args.str_or("out", "BENCH_serving.json");

    // inference lifecycle: build → convert → program (t = t0)
    let mut rng = Rng::new(seed);
    let mut model = mlp(&dims, Backend::Analog, &cfg, &mut rng);
    let mut icfg = aihwsim::config::InferenceRPUConfig::default();
    if let Some(json) = &cfg_json {
        if json.get("inference").is_some() {
            match loader::inference_options_from_json(json) {
                Ok(o) => icfg = o.config,
                Err(e) => {
                    eprintln!("config error: {e}");
                    std::process::exit(2);
                }
            }
        }
    }
    model.convert_to_inference(&icfg, &mut rng);
    model.program();
    info(&model.summary());
    info(&format!(
        "serve-bench: {} worker threads, {rpc} requests/client, max_batch {max_batch}",
        aihwsim::util::threadpool::num_threads()
    ));

    let mut entries = Vec::new();
    println!(
        "{:>8} {:>12} {:>8} {:>12} {:>10} {:>10}",
        "clients", "window_us", "mode", "req/s", "p50_ms", "p99_ms"
    );
    for &c in &clients {
        // serial reference: every request is its own batch
        let (rps, p50, p99) = serve_cell(&model, c, 0, 1, rpc, dims[0], seed);
        println!("{c:>8} {:>12} {:>8} {rps:>12.0} {p50:>10.3} {p99:>10.3}", 0, "serial");
        entries.push(Json::obj(vec![
            ("clients", Json::num(c as f64)),
            ("batch_window_us", Json::num(0.0)),
            ("mode", Json::str("serial")),
            ("requests_per_s", Json::num(rps)),
            ("p50_ms", Json::num(p50)),
            ("p99_ms", Json::num(p99)),
        ]));
        for &w in &windows {
            let (rps, p50, p99) = serve_cell(&model, c, w, max_batch, rpc, dims[0], seed);
            println!("{c:>8} {w:>12} {:>8} {rps:>12.0} {p50:>10.3} {p99:>10.3}", "micro");
            entries.push(Json::obj(vec![
                ("clients", Json::num(c as f64)),
                ("batch_window_us", Json::num(w as f64)),
                ("mode", Json::str("micro")),
                ("requests_per_s", Json::num(rps)),
                ("p50_ms", Json::num(p50)),
                ("p99_ms", Json::num(p99)),
            ]));
        }
    }
    let doc = Json::obj(vec![
        ("bench", Json::str("serving")),
        ("dims", Json::arr_f32(&dims.iter().map(|&d| d as f32).collect::<Vec<f32>>())),
        ("max_batch", Json::num(max_batch as f64)),
        ("requests_per_client", Json::num(rpc as f64)),
        ("threads", Json::num(aihwsim::util::threadpool::num_threads() as f64)),
        (
            "cores",
            Json::num(
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) as f64
            ),
        ),
        ("backend", Json::str(aihwsim::tile::backend::global_default().name())),
        (
            "cpu_features",
            Json::Arr(
                aihwsim::tile::backend::detected_features()
                    .iter()
                    .map(|f| Json::str(f))
                    .collect(),
            ),
        ),
        ("results", Json::Arr(entries)),
    ]);
    std::fs::write(&out, doc.to_string_pretty()).unwrap_or_else(|e| {
        eprintln!("serve-bench: cannot write {out}: {e}");
        std::process::exit(1);
    });
    info(&format!("wrote {out}"));
}

/// Accuracy-vs-fault-rate robustness grid (`BENCH_faults.json`): train a
/// small FP reference MLP once, then run the full (time × repeat) drift
/// sweep at every fault rate, injecting stuck-cell defects through the
/// inference config at program time (see [`FaultModel::stuck`]). Rate 0
/// reproduces the plain drift sweep bit-for-bit, so the rate axis
/// isolates the hard-fault effect.
fn cmd_fault_sweep(args: &Args) {
    let seed = args.u64_or("seed", 42);
    let (cfg, cfg_json) = load_config(args);
    let dims = usize_list(args, "dims", &[64, 32, 4]);
    if dims.len() < 2 || dims.iter().any(|&d| d == 0) {
        eprintln!("--dims: need at least two positive layer sizes");
        std::process::exit(2);
    }
    let side = (dims[0] as f64).sqrt() as usize;
    if side * side != dims[0] {
        eprintln!("--dims: first layer size must be a square (synthetic side² images)");
        std::process::exit(2);
    }
    let rates: Vec<f64> = match args.f32_list("rates") {
        None => vec![0.0, 0.001, 0.01, 0.05, 0.1],
        Some(Ok(v)) if !v.is_empty() => v.into_iter().map(|r| r as f64).collect(),
        Some(Ok(_)) => {
            eprintln!("--rates: empty schedule");
            std::process::exit(2);
        }
        Some(Err(e)) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if rates.iter().any(|r| !r.is_finite() || !(0.0..=1.0).contains(r)) {
        eprintln!("--rates: fault rates must be probabilities in [0, 1]");
        std::process::exit(2);
    }
    let out = args.str_or("out", "BENCH_faults.json");

    // inference options: combined --config "inference" section, then CLI
    let mut iopts = aihwsim::config::loader::InferenceOptions::default();
    if let Some(json) = &cfg_json {
        if json.get("inference").is_some() {
            match loader::inference_options_from_json(json) {
                Ok(o) => iopts = o,
                Err(e) => {
                    eprintln!("config error: {e}");
                    std::process::exit(2);
                }
            }
        }
    }
    if let Some(times) = t_inference_list(args) {
        iopts.t_inference = times;
    }
    let n_repeats = args.usize_or("n-reps", iopts.n_repeats);

    // train the FP reference once; every (rate × repeat × time) cell
    // reprograms these same weights onto freshly faulted devices
    let classes = *dims.last().unwrap();
    let samples = args.usize_or("samples", 240);
    let mut rng = Rng::new(seed);
    let ds = synthetic_images(samples, classes, side, 1, &mut rng);
    let mut model = mlp(&dims, Backend::FloatingPoint, &cfg, &mut rng);
    let tc = trainer::TrainConfig {
        epochs: args.usize_or("epochs", 10),
        batch_size: args.usize_or("batch", 16),
        lr: args.f32_or("lr", 0.5),
        seed,
        log_every: 0,
        csv_path: None,
    };
    let report = trainer::train_classifier(&mut model, &ds, &ds, &tc);
    info(&format!("fault-sweep: FP reference trained, acc {:.3}", report.final_test_acc()));
    let layers = collect_linear_layers(&mut model);
    let mapping = cfg.mapping.clone();
    let icfg = iopts.config.clone();
    let build = |s: u64, rate: f64| {
        let mut icfg_r = icfg.clone();
        icfg_r.faults = FaultModel::stuck(rate);
        let mut r = Rng::new(s);
        let mut net = mlp_from_layers(&layers, &mapping, &mut r);
        net.convert_to_inference(&icfg_r, &mut r);
        net
    };
    let eval_cfg =
        DriftEvalConfig { times: iopts.t_inference.clone(), n_repeats, batch: 32, seed };
    let sweep = fault_sweep(&build, &ds, &rates, &eval_cfg);

    let mut entries = Vec::new();
    println!("{:>10} {:>12} {:>10} {:>10} {:>10}", "rate", "t_seconds", "acc_mean", "acc_std", "defects");
    for (rate, report) in &sweep {
        // measured defect fraction: program the first repeat's instance
        // once and merge the per-layer grid fault counters
        let mut probe = build(repeat_seed(seed, 0), *rate);
        probe.program();
        let mut stats = FaultStats::default();
        for idx in (0..).step_by(2).take(dims.len() - 1) {
            if let Some(lin) = probe
                .module_mut(idx)
                .as_any_mut()
                .and_then(|a| a.downcast_mut::<AnalogLinear>())
            {
                if let Some(s) = lin.grid_mut().fault_stats() {
                    stats.merge(&s);
                }
            }
        }
        let frac = stats.fraction_defective();
        for p in &report.points {
            println!(
                "{rate:>10.4} {t:>12.0} {m:>10.3} {s:>10.3} {frac:>10.4}",
                t = p.t,
                m = p.acc_mean,
                s = p.acc_std,
            );
            entries.push(Json::obj(vec![
                ("fault_rate", Json::num(*rate)),
                ("t_seconds", Json::num(p.t as f64)),
                ("acc_mean", Json::num(p.acc_mean)),
                ("acc_std", Json::num(p.acc_std)),
                ("measured_fault_fraction", Json::num(frac)),
            ]));
        }
    }
    let doc = Json::obj(vec![
        ("bench", Json::str("faults")),
        ("dims", Json::arr_f32(&dims.iter().map(|&d| d as f32).collect::<Vec<f32>>())),
        ("rates", Json::arr_f32(&rates.iter().map(|&r| r as f32).collect::<Vec<f32>>())),
        ("t_inference", Json::arr_f32(&iopts.t_inference)),
        ("n_repeats", Json::num(n_repeats as f64)),
        ("fp_reference_acc", Json::num(report.final_test_acc())),
        ("threads", Json::num(aihwsim::util::threadpool::num_threads() as f64)),
        ("backend", Json::str(aihwsim::tile::backend::global_default().name())),
        (
            "cpu_features",
            Json::Arr(
                aihwsim::tile::backend::detected_features()
                    .iter()
                    .map(|f| Json::str(f))
                    .collect(),
            ),
        ),
        ("results", Json::Arr(entries)),
    ]);
    std::fs::write(&out, doc.to_string_pretty()).unwrap_or_else(|e| {
        eprintln!("fault-sweep: cannot write {out}: {e}");
        std::process::exit(1);
    });
    info(&format!("wrote {out}"));
}

/// Design-space sweep (`BENCH_sweeps.json`): train a small FP reference
/// MLP once, then evaluate every (slices × adc_bits × fault_rate) cell of
/// the hardware grid over the full (time × repeat) drift schedule through
/// the programmed-state snapshot cache — program once per
/// `(repeat, slices, fault_rate)` class, fan the `(t_inference ×
/// adc_bits)` points out over clones (see
/// [`aihwsim::coordinator::evaluator::design_sweep_with_observer`]).
/// Rows are bit-deterministic at any `--threads` and bit-identical to
/// the per-point engine (`--bench-uncached` re-runs it to time the
/// speedup and asserts row equality). CSV rows stream to `--csv` in grid
/// order as cells complete, with per-cell progress on stderr.
fn cmd_sweep(args: &Args) {
    let seed = args.u64_or("seed", 42);
    let (cfg, cfg_json) = load_config(args);
    let dims = usize_list(args, "dims", &[64, 32, 4]);
    if dims.len() < 2 || dims.iter().any(|&d| d == 0) {
        eprintln!("--dims: need at least two positive layer sizes");
        std::process::exit(2);
    }
    let side = (dims[0] as f64).sqrt() as usize;
    if side * side != dims[0] {
        eprintln!("--dims: first layer size must be a square (synthetic side² images)");
        std::process::exit(2);
    }
    let slices = usize_list(args, "slices", &[1, 2, 4]);
    let adc_bits: Vec<u32> =
        usize_list(args, "adc-bits", &[0, 8]).into_iter().map(|b| b as u32).collect();
    let rates: Vec<f64> = match args.f32_list("rates") {
        None => vec![0.0],
        Some(Ok(v)) if !v.is_empty() => v.into_iter().map(|r| r as f64).collect(),
        Some(Ok(_)) => {
            eprintln!("--rates: empty schedule");
            std::process::exit(2);
        }
        Some(Err(e)) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if rates.iter().any(|r| !r.is_finite() || !(0.0..=1.0).contains(r)) {
        eprintln!("--rates: fault rates must be probabilities in [0, 1]");
        std::process::exit(2);
    }
    let out = args.str_or("out", "BENCH_sweeps.json");

    // inference options: combined --config "inference" section, then CLI
    let mut iopts = aihwsim::config::loader::InferenceOptions::default();
    if let Some(json) = &cfg_json {
        if json.get("inference").is_some() {
            match loader::inference_options_from_json(json) {
                Ok(o) => iopts = o,
                Err(e) => {
                    eprintln!("config error: {e}");
                    std::process::exit(2);
                }
            }
        }
    }
    if let Some(times) = t_inference_list(args) {
        iopts.t_inference = times;
    }
    let n_repeats = args.usize_or("n-reps", iopts.n_repeats);
    // ADC range policy for the swept bits (the per-cell bits override
    // whatever the config file set; the range policy is grid-wide)
    let adc_range = match args.get("adc-range") {
        None => iopts.config.forward.adc.range,
        Some("auto_max") => AdcRange::AutoMax,
        Some("per_column") => AdcRange::PerColumn,
        Some("fixed") => match args.get("adc-fixed-range").and_then(|v| v.parse::<f32>().ok()) {
            Some(r) => AdcRange::Fixed(r),
            None => {
                eprintln!("--adc-range fixed needs --adc-fixed-range <full scale>");
                std::process::exit(2);
            }
        },
        Some(other) => {
            eprintln!("--adc-range: expected auto_max|per_column|fixed, got '{other}'");
            std::process::exit(2);
        }
    };

    let cells = sweep_grid(&slices, &adc_bits, &rates);
    // validate every distinct hardware configuration up front — bad knobs
    // are config errors (exit 2), not mid-sweep panics
    for cell in &cells {
        let mut probe = iopts.config.clone();
        probe.slicing.slices = cell.slices;
        probe.forward.adc = AdcParameters { bits: cell.adc_bits, range: adc_range };
        probe.faults = FaultModel::stuck(cell.fault_rate);
        if let Err(e) = probe.validate().and_then(|_| probe.forward.validate()) {
            eprintln!("config error: {e}");
            std::process::exit(2);
        }
    }

    // train the FP reference once; every cell reprograms these same
    // weights onto its own hardware variant
    let classes = *dims.last().unwrap();
    let samples = args.usize_or("samples", 240);
    let mut rng = Rng::new(seed);
    let ds = synthetic_images(samples, classes, side, 1, &mut rng);
    let mut model = mlp(&dims, Backend::FloatingPoint, &cfg, &mut rng);
    let tc = trainer::TrainConfig {
        epochs: args.usize_or("epochs", 10),
        batch_size: args.usize_or("batch", 16),
        lr: args.f32_or("lr", 0.5),
        seed,
        log_every: 0,
        csv_path: None,
    };
    let report = trainer::train_classifier(&mut model, &ds, &ds, &tc);
    info(&format!("sweep: FP reference trained, acc {:.3}", report.final_test_acc()));
    let layers = collect_linear_layers(&mut model);
    let mapping = cfg.mapping.clone();
    let icfg = iopts.config.clone();
    let build = |s: u64, cell: &aihwsim::coordinator::SweepCell| {
        let mut icfg_c = icfg.clone();
        icfg_c.slicing.slices = cell.slices;
        icfg_c.forward.adc = AdcParameters { bits: cell.adc_bits, range: adc_range };
        icfg_c.faults = FaultModel::stuck(cell.fault_rate);
        let mut r = Rng::new(s);
        let mut net = mlp_from_layers(&layers, &mapping, &mut r);
        net.convert_to_inference(&icfg_c, &mut r);
        net
    };
    let eval_cfg =
        DriftEvalConfig { times: iopts.t_inference.clone(), n_repeats, batch: 32, seed };
    let n_points = cells.len() * iopts.t_inference.len() * n_repeats;
    info(&format!(
        "sweep: {} cells × {} times × {n_repeats} repeats = {n_points} points on {} threads",
        cells.len(),
        iopts.t_inference.len(),
        aihwsim::util::threadpool::num_threads()
    ));

    // CSV header lands on disk before the sweep starts; rows stream in
    // grid order as cells complete (buffered until the next-in-order cell
    // is done), with per-cell progress on stderr
    let csv = args.get("csv").map(|p| {
        let mut c = CsvLogger::create(
            p,
            &["slices", "adc_bits", "fault_rate", "t_seconds", "acc_mean", "acc_std"],
        )
        .unwrap();
        c.flush().unwrap();
        c
    });
    println!(
        "{:>8} {:>9} {:>10} {:>12} {:>10} {:>10}",
        "slices", "adc_bits", "rate", "t_seconds", "acc_mean", "acc_std"
    );
    struct SweepStream {
        pending: Vec<Option<Vec<SweepRow>>>,
        next: usize,
        finished: usize,
        csv: Option<CsvLogger>,
    }
    impl SweepStream {
        fn flush_ready(&mut self) {
            while self.next < self.pending.len() {
                let Some(rows) = self.pending[self.next].take() else { break };
                for row in &rows {
                    let p = &row.point;
                    println!(
                        "{sl:>8} {ab:>9} {rate:>10.4} {t:>12.0} {m:>10.3} {s:>10.3}",
                        sl = row.cell.slices,
                        ab = row.cell.adc_bits,
                        rate = row.cell.fault_rate,
                        t = p.t,
                        m = p.acc_mean,
                        s = p.acc_std,
                    );
                    if let Some(c) = self.csv.as_mut() {
                        c.row(&[
                            row.cell.slices as f64,
                            row.cell.adc_bits as f64,
                            row.cell.fault_rate,
                            p.t as f64,
                            p.acc_mean,
                            p.acc_std,
                        ])
                        .unwrap();
                    }
                }
                if let Some(c) = self.csv.as_mut() {
                    c.flush().unwrap();
                }
                self.next += 1;
            }
        }
    }
    let stream = std::sync::Mutex::new(SweepStream {
        pending: vec![None; cells.len()],
        next: 0,
        finished: 0,
        csv,
    });
    let t_cached = std::time::Instant::now();
    let sweep_report = design_sweep_with_observer(&build, &ds, &cells, &eval_cfg, |ci, rows| {
        let mut st = stream.lock().unwrap();
        st.pending[ci] = Some(rows.to_vec());
        st.finished += 1;
        eprintln!(
            "sweep: cell {}/{} done (slices={}, adc_bits={}, rate={})",
            st.finished,
            cells.len(),
            cells[ci].slices,
            cells[ci].adc_bits,
            cells[ci].fault_rate
        );
        st.flush_ready();
    });
    let cached_ms = t_cached.elapsed().as_secs_f64() * 1e3;
    let rows = &sweep_report.rows;
    info(&format!(
        "sweep: {} program-and-verify runs for {} points ({} classes × {n_repeats} repeats) in {cached_ms:.0} ms",
        sweep_report.n_programmings, sweep_report.n_points, sweep_report.n_classes
    ));

    // --bench-uncached: time the per-point reference engine on the same
    // grid, assert bitwise row equality, and report the snapshot speedup
    let mut uncached_ms = None;
    if args.has_flag("bench-uncached") {
        let t_un = std::time::Instant::now();
        let reference = design_sweep_uncached(&build, &ds, &cells, &eval_cfg);
        let ms = t_un.elapsed().as_secs_f64() * 1e3;
        assert_eq!(rows.len(), reference.len());
        for (a, b) in rows.iter().zip(reference.iter()) {
            assert_eq!(
                a.point.acc, b.point.acc,
                "cached sweep diverged from the per-point engine at cell {:?} t {}",
                a.cell, a.point.t
            );
        }
        info(&format!(
            "sweep: cached {cached_ms:.0} ms vs uncached {ms:.0} ms — {:.2}x speedup, rows bitwise identical",
            ms / cached_ms.max(1e-9)
        ));
        uncached_ms = Some(ms);
    }

    let mut entries = Vec::new();
    for row in rows {
        let p = &row.point;
        entries.push(Json::obj(vec![
            ("slices", Json::num(row.cell.slices as f64)),
            ("adc_bits", Json::num(row.cell.adc_bits as f64)),
            ("fault_rate", Json::num(row.cell.fault_rate)),
            ("t_seconds", Json::num(p.t as f64)),
            ("acc_mean", Json::num(p.acc_mean)),
            ("acc_std", Json::num(p.acc_std)),
        ]));
    }
    let mut doc_fields = vec![
        ("bench", Json::str("sweeps")),
        ("dims", Json::arr_f32(&dims.iter().map(|&d| d as f32).collect::<Vec<f32>>())),
        ("slices", Json::arr_f32(&slices.iter().map(|&s| s as f32).collect::<Vec<f32>>())),
        ("adc_bits", Json::arr_f32(&adc_bits.iter().map(|&b| b as f32).collect::<Vec<f32>>())),
        ("rates", Json::arr_f32(&rates.iter().map(|&r| r as f32).collect::<Vec<f32>>())),
        ("t_inference", Json::arr_f32(&iopts.t_inference)),
        ("n_repeats", Json::num(n_repeats as f64)),
        ("n_points", Json::num(sweep_report.n_points as f64)),
        ("n_classes", Json::num(sweep_report.n_classes as f64)),
        ("n_programmings", Json::num(sweep_report.n_programmings as f64)),
        ("cached_ms", Json::num(cached_ms)),
        ("fp_reference_acc", Json::num(report.final_test_acc())),
        ("threads", Json::num(aihwsim::util::threadpool::num_threads() as f64)),
    ];
    if let Some(ms) = uncached_ms {
        doc_fields.push(("uncached_ms", Json::num(ms)));
        doc_fields.push(("speedup", Json::num(ms / cached_ms.max(1e-9))));
    }
    doc_fields.push(("backend", Json::str(aihwsim::tile::backend::global_default().name())));
    doc_fields.push((
        "cpu_features",
        Json::Arr(
            aihwsim::tile::backend::detected_features().iter().map(|f| Json::str(f)).collect(),
        ),
    ));
    doc_fields.push(("results", Json::Arr(entries)));
    let doc = Json::obj(doc_fields);
    std::fs::write(&out, doc.to_string_pretty()).unwrap_or_else(|e| {
        eprintln!("sweep: cannot write {out}: {e}");
        std::process::exit(1);
    });
    info(&format!("wrote {out}"));
}

fn cmd_presets() {
    for name in presets::SINGLE_PRESET_NAMES {
        let cfg = presets::by_name(name).unwrap();
        println!("{name:16} dw_min={:.5} bound={:.2}", cfg.dw_min(), cfg.w_bound());
    }
    println!("tiki_taka        (transfer compound of 2× reram_sb)");
}

fn main() {
    let args = Args::from_env();
    apply_thread_override(&args);
    apply_backend_override(&args);
    match args.subcommand() {
        Some("train") => cmd_train(&args),
        Some("infer-drift") => cmd_infer_drift(&args),
        Some("response") => cmd_response(&args),
        Some("drift") => cmd_drift(&args),
        Some("e2e") => cmd_e2e(&args),
        Some("serve-bench") => cmd_serve_bench(&args),
        Some("fault-sweep") => cmd_fault_sweep(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("presets") => cmd_presets(),
        _ => usage(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threads_flag_overrides_env() {
        // no other unit test in this binary touches AIHWSIM_THREADS, so
        // the process-global env var is safe to probe here
        std::env::set_var("AIHWSIM_THREADS", "2");
        let args = Args::parse(&["x".to_string(), "--threads".to_string(), "3".to_string()]);
        apply_thread_override(&args);
        assert_eq!(std::env::var("AIHWSIM_THREADS").unwrap(), "3");
        assert_eq!(aihwsim::util::threadpool::num_threads(), 3);
        // absent flag: leaves the env var alone
        let args = Args::parse(&["x".to_string()]);
        apply_thread_override(&args);
        assert_eq!(aihwsim::util::threadpool::num_threads(), 3);
        std::env::remove_var("AIHWSIM_THREADS");
    }

    #[test]
    fn kernel_backend_flag_sets_env() {
        // no other unit test in this binary touches AIHWSIM_BACKEND, so
        // the process-global env var is safe to probe here
        std::env::remove_var("AIHWSIM_BACKEND");
        let args =
            Args::parse(&["x".to_string(), "--kernel-backend".to_string(), "tiled".to_string()]);
        apply_backend_override(&args);
        assert_eq!(std::env::var("AIHWSIM_BACKEND").unwrap(), "tiled");
        assert_eq!(aihwsim::tile::backend::resolve(ForwardBackend::Auto, false).name(), "tiled");
        // `--backend` doubles as the kernel selector when its value names
        // a kernel backend (train's analog|fp values never parse here)
        let args = Args::parse(&["x".to_string(), "--backend".to_string(), "scalar".to_string()]);
        apply_backend_override(&args);
        assert_eq!(std::env::var("AIHWSIM_BACKEND").unwrap(), "scalar");
        let args = Args::parse(&["x".to_string(), "--backend".to_string(), "analog".to_string()]);
        std::env::remove_var("AIHWSIM_BACKEND");
        apply_backend_override(&args);
        assert!(std::env::var("AIHWSIM_BACKEND").is_err());
    }

    #[test]
    fn usize_list_parses() {
        let args = Args::parse(&["x".to_string(), "--clients".to_string(), "1, 4,8".to_string()]);
        assert_eq!(usize_list(&args, "clients", &[7]), vec![1, 4, 8]);
        assert_eq!(usize_list(&args, "missing", &[7]), vec![7]);
    }
}
