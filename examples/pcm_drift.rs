//! E3 — PCM conductance drift (paper Fig. 3C).
//!
//! Programs 2000 devices per conductance target with the calibrated
//! statistical model (programming noise → drift → read noise) and tracks
//! the population mean ± std from t0 = 25 s to one year — reproducing the
//! temporal evolution plot of Fig. 3C, including the growing spread from
//! device-to-device drift-exponent variability.
//!
//! Run: `cargo run --release --example pcm_drift`
//! Output: results/fig3c_pcm_drift.csv

use aihwsim::coordinator::experiments::pcm_drift;
use aihwsim::util::logging::CsvLogger;
use aihwsim::util::stats::linfit;

fn main() {
    std::fs::create_dir_all("results").unwrap();
    let times: Vec<f32> = (0..25).map(|i| 25.0 * 10f32.powf(i as f32 * 0.25)).collect();
    let targets = [22.5f32, 15.0, 7.5, 2.5];
    let tr = pcm_drift(&targets, &times, 2000, 1);
    let mut csv =
        CsvLogger::create("results/fig3c_pcm_drift.csv", &["t_seconds", "target_us", "mean_us", "std_us"])
            .unwrap();
    for (g, means, stds) in &tr.levels {
        for (i, &t) in tr.times.iter().enumerate() {
            csv.row(&[t as f64, *g as f64, means[i], stds[i]]).unwrap();
        }
        // fit the drift exponent: log g = log g0 − ν·log(t/t0)
        let lx: Vec<f64> = tr.times.iter().map(|&t| (t as f64 / 25.0).log10()).collect();
        let ly: Vec<f64> = means.iter().map(|&m| m.max(1e-6).log10()).collect();
        let (_, slope) = linfit(&lx, &ly);
        println!(
            "target {g:>5.1} µS: mean {:.2} → {:.2} µS over 1y, fitted ν ≈ {:.3}",
            means[0],
            means.last().unwrap(),
            -slope
        );
        assert!(-slope > 0.01 && -slope < 0.15, "drift exponent in the PCM range");
    }
    csv.flush().unwrap();
    println!("# wrote results/fig3c_pcm_drift.csv");
    println!("# pcm_drift OK (Fig. 3C data regenerated)");
}
