//! E1 — Quickstart (paper Fig. 2 / aihwkit example 01).
//!
//! Defines an `AnalogLinear(4, 2)` layer on a ReRAM-ES crossbar, trains it
//! with the analog-pulsed `AnalogSGD` on a toy regression task, and prints
//! the loss curve. This is the Rust rendition of the paper's code listing:
//!
//! ```text
//! rpu_config = SingleRPUConfig(device=ReRamESPresetDevice())
//! model      = AnalogLinear(4, 2, bias=True, rpu_config=config)
//! opt        = AnalogSGD(model.parameters(), lr=0.1)
//! for epoch in range(100): ... loss.backward(); opt.step()
//! ```
//!
//! Run: `cargo run --release --example quickstart`

use aihwsim::config::{presets, RPUConfig};
use aihwsim::data::regression_toy;
use aihwsim::nn::loss::mse_loss;
use aihwsim::nn::{AnalogLinear, Module};
use aihwsim::optim::AnalogSGD;
use aihwsim::util::rng::Rng;

fn main() {
    // Define crossbar (RPU) config with the ReRAM exponential-step preset.
    let rpu_config = RPUConfig::single(presets::reram_es());
    let mut rng = Rng::new(42);

    // Define a single-layer analog network.
    let mut model = AnalogLinear::new(4, 2, true, rpu_config, &mut rng);

    // Define the analog-aware optimizer.
    let mut opt = AnalogSGD::new(0.1);

    // Data: y = W·x + b for a fixed random W.
    let (x, y) = regression_toy(32, &mut rng);

    println!("epoch,loss");
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for epoch in 0..200 {
        let pred = model.forward(&x); // analog forward pass
        let (loss, grad) = mse_loss(&pred, &y);
        model.backward(&grad); // analog backward pass
        opt.step(&mut model); // analog pulsed update
        if epoch == 0 {
            first = loss;
        }
        last = loss;
        if epoch % 20 == 0 || epoch == 199 {
            println!("{epoch},{loss:.5}");
        }
    }
    println!("# loss {first:.4} -> {last:.4} (device: ReRam-ES, pulsed SGD)");
    assert!(last < first * 0.7, "training must reduce the loss");
    println!("# quickstart OK");
}
