//! E5 — Tiki-Taka vs plain analog SGD (paper Fig. 4 / §4).
//!
//! Trains the same MLP on the same synthetic-image task twice:
//!   (a) plain pulsed SGD on a single ReRam-SB device per crosspoint,
//!   (b) the Tiki-Taka TransferCompound (gradient tile A + weight tile C,
//!       periodic noisy column transfer) of Gokmen & Haensch 2020.
//! On noisy, asymmetric devices Tiki-Taka is expected to reach a better
//! loss/accuracy — the reason the paper ships the compound construct.
//!
//! Run: `cargo run --release --example tiki_taka`
//! Output: results/fig4_tiki_taka.csv

use aihwsim::coordinator::experiments::tiki_taka_comparison;
use aihwsim::data::synthetic_images;
use aihwsim::util::logging::CsvLogger;
use aihwsim::util::rng::Rng;

fn main() {
    std::fs::create_dir_all("results").unwrap();
    let mut rng = Rng::new(33);
    // one generator call → one set of class prototypes, split train/test
    let (train, test) = synthetic_images(520, 4, 8, 1, &mut rng).split(120);
    let epochs = 30;
    let (sgd, tt) = tiki_taka_comparison(&train, &test, &[64, 4], epochs, 7);

    let mut csv = CsvLogger::create(
        "results/fig4_tiki_taka.csv",
        &["epoch", "sgd_loss", "sgd_acc", "tiki_taka_loss", "tiki_taka_acc"],
    )
    .unwrap();
    for e in 0..epochs {
        csv.row(&[
            e as f64,
            sgd.epoch_loss[e],
            sgd.epoch_test_acc[e],
            tt.epoch_loss[e],
            tt.epoch_test_acc[e],
        ])
        .unwrap();
    }
    csv.flush().unwrap();
    println!(
        "plain analog SGD : final loss {:.4}, test acc {:.3}",
        sgd.final_loss(),
        sgd.final_test_acc()
    );
    println!(
        "tiki-taka        : final loss {:.4}, test acc {:.3}",
        tt.final_loss(),
        tt.final_test_acc()
    );
    println!("# wrote results/fig4_tiki_taka.csv");
    // Both must learn; Tiki-Taka should be at least competitive.
    assert!(tt.final_test_acc() > 0.45, "tiki-taka must learn");
    println!("# tiki_taka OK (Fig. 4 construct exercised)");
}
