//! E7 — End-to-end driver: the full three-layer stack on a real workload.
//!
//! The Rust coordinator (L3) batches a 28×28 synthetic-image dataset and
//! drives the AOT-compiled `hwa_train_step` HLO artifact — the JAX model
//! (L2) whose analog forward is the fused Pallas kernel (L1) — through the
//! PJRT CPU client for several hundred steps, logging the loss curve, then
//! evaluates with the `analog_infer` artifact. It also runs the
//! `fp_train_step` baseline to report the analog/FP runtime ratio on the
//! *same* substrate (the paper's footnote-3 claim is a ratio, 2-5×).
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example e2e_train [-- --steps 300]
//! Output: results/e2e_loss.csv

use aihwsim::coordinator::hwa_pipeline::HwaPipeline;
use aihwsim::data::synthetic_images;
use aihwsim::runtime::Runtime;
use aihwsim::util::argparse::Args;
use aihwsim::util::logging::CsvLogger;
use aihwsim::util::rng::Rng;

fn main() {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 300);
    std::fs::create_dir_all("results").unwrap();
    let dir = Runtime::default_dir();
    let mut pipe = match HwaPipeline::new(&dir, 42) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("cannot open artifacts ({e:#}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!("PJRT platform: {} | batch {} | MLP 784-256-128-10", pipe.platform(), pipe.batch());
    let mut rng = Rng::new(7);
    let ds = synthetic_images(2048, 10, 28, 1, &mut rng);

    // --- hardware-aware training through the full stack ---
    let rep = pipe.train("hwa_train_step", &ds, steps, 0.1, 25).expect("hwa training");
    let acc = pipe.evaluate(&ds).expect("analog inference eval");
    let mut csv = CsvLogger::create("results/e2e_loss.csv", &["step", "loss"]).unwrap();
    for (i, &l) in rep.step_loss.iter().enumerate() {
        csv.row(&[i as f64, l as f64]).unwrap();
    }
    csv.flush().unwrap();
    let first: f32 = rep.step_loss[..10.min(rep.step_loss.len())].iter().sum::<f32>()
        / 10.min(rep.step_loss.len()) as f32;
    let last: f32 = rep.step_loss[rep.step_loss.len().saturating_sub(10)..].iter().sum::<f32>()
        / 10.0_f32.min(rep.step_loss.len() as f32);
    println!(
        "HWA: {} steps, {:.1} s ({:.1} ms/step, {:.0}% in PJRT), loss {first:.3} -> {last:.3}, analog-inference acc {acc:.3}",
        rep.steps,
        rep.wall_s,
        1e3 * rep.wall_s / rep.steps as f64,
        100.0 * rep.exec_s / rep.wall_s
    );

    // --- FP baseline on the same substrate (runtime-ratio claim) ---
    let mut pipe_fp = HwaPipeline::new(&dir, 42).expect("runtime");
    let rep_fp = pipe_fp.train("fp_train_step", &ds, steps.min(100), 0.1, 0).expect("fp training");
    let ms_hwa = 1e3 * rep.wall_s / rep.steps as f64;
    let ms_fp = 1e3 * rep_fp.wall_s / rep_fp.steps as f64;
    println!(
        "FP baseline: {:.1} ms/step -> analog/FP runtime ratio {:.1}x (paper reports 2-5x on GPU)",
        ms_fp,
        ms_hwa / ms_fp
    );

    assert!(last < first * 0.8, "loss must decrease: {first} -> {last}");
    assert!(acc > 0.3, "analog inference accuracy {acc} too low");
    println!("# wrote results/e2e_loss.csv");
    println!("# e2e_train OK (all three layers composed)");
}
