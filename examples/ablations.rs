//! Ablation study over the tile's dynamic-range management schemes — the
//! design choices §3 inherits from Gokmen & Vlasov 2016:
//!
//!   * noise management (NM): dynamic input scaling to the DAC range
//!   * bound management (BM): iterative output rescaling on ADC clip
//!   * update management (UM): balancing the x/d pulse probabilities
//!   * update-BL management (UBLM): shortening trains for small gradients
//!
//! Each is switched off in isolation and the same analog MLP is trained on
//! the same data; the deltas show why the defaults are on.
//!
//! Run: `cargo run --release --example ablations`
//! Output: results/ablations.csv

use aihwsim::config::{presets, BoundManagement, DeviceConfig, NoiseManagement, RPUConfig};
use aihwsim::coordinator::trainer::{train_classifier, TrainConfig};
use aihwsim::data::synthetic_images;
use aihwsim::nn::sequential::{mlp, Backend};
use aihwsim::util::logging::CsvLogger;
use aihwsim::util::rng::Rng;

fn run(label: &str, cfg: &RPUConfig, csv: &mut CsvLogger) -> (f64, f64) {
    let mut rng = Rng::new(11);
    let (train, test) = synthetic_images(520, 4, 8, 1, &mut rng).split(120);
    let mut model = mlp(&[64, 4], Backend::Analog, cfg, &mut rng);
    let tc =
        TrainConfig { epochs: 15, batch_size: 16, lr: 0.1, seed: 3, log_every: 0, csv_path: None };
    let rep = train_classifier(&mut model, &train, &test, &tc);
    let (loss, acc) = (rep.final_loss(), rep.final_test_acc());
    println!("  {label:28} loss {loss:.4}  test acc {acc:.3}");
    csv.row_str(&[label.to_string(), format!("{loss:.5}"), format!("{acc:.4}")]).unwrap();
    (loss, acc)
}

fn base_config() -> RPUConfig {
    let mut cfg = RPUConfig::default();
    cfg.device = DeviceConfig::Single(presets::gokmen_vlasov());
    cfg.weight_scaling_omega = 0.6;
    cfg
}

fn main() {
    std::fs::create_dir_all("results").unwrap();
    let mut csv = CsvLogger::create("results/ablations.csv", &["config", "loss", "acc"]).unwrap();
    println!("ablations (analog MLP 64-4, ConstantStep devices, 15 epochs):");

    let (_, acc_all) = run("all management on (default)", &base_config(), &mut csv);

    let mut no_nm = base_config();
    no_nm.forward.noise_management = NoiseManagement::None;
    no_nm.backward.noise_management = NoiseManagement::None;
    run("no noise management", &no_nm, &mut csv);

    let mut no_bm = base_config();
    no_bm.forward.bound_management = BoundManagement::None;
    no_bm.backward.bound_management = BoundManagement::None;
    run("no bound management", &no_bm, &mut csv);

    let mut no_um = base_config();
    no_um.update.update_management = false;
    run("no update management", &no_um, &mut csv);

    let mut no_ublm = base_config();
    no_ublm.update.update_bl_management = false;
    run("no update-BL management", &no_ublm, &mut csv);

    let mut coarse_adc = base_config();
    coarse_adc.forward.out_res = 1.0 / 30.0; // 5-bit ADC
    coarse_adc.backward.out_res = 1.0 / 30.0;
    run("5-bit ADC (vs 9-bit)", &coarse_adc, &mut csv);

    let mut bl7 = base_config();
    bl7.update.desired_bl = 7;
    run("BL = 7 (vs 31)", &bl7, &mut csv);

    csv.flush().unwrap();
    println!("# baseline accuracy {acc_all:.3}; deltas show each scheme's contribution");
    assert!(acc_all > 0.6, "baseline must train well, got {acc_all}");
    println!("# wrote results/ablations.csv");
    println!("# ablations OK");
}
