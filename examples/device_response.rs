//! E2 — Device pulse response (paper Fig. 3B).
//!
//! Applies 1000 up then 1000 down pulses to a population of 64 simulated
//! devices for each ReRAM preset and records mean ± std of the weight plus
//! the noise-free ideal response — the data behind Fig. 3B's comparison of
//! experimental and simulated ReRAM response curves.
//!
//! Run: `cargo run --release --example device_response`
//! Output: results/fig3b_<preset>.csv

use aihwsim::coordinator::experiments::device_response;
use aihwsim::util::logging::CsvLogger;

fn main() {
    std::fs::create_dir_all("results").unwrap();
    for preset in ["reram_es", "reram_sb"] {
        let tr = device_response(preset, 64, 1000, 1);
        let path = format!("results/fig3b_{preset}.csv");
        let mut csv = CsvLogger::create(&path, &["pulse", "mean", "std", "ideal"]).unwrap();
        for i in 0..tr.pulse.len() {
            csv.row(&[tr.pulse[i] as f64, tr.mean[i], tr.std[i], tr.ideal[i]]).unwrap();
        }
        csv.flush().unwrap();
        // summarize the curve shape in the console
        let peak = tr.mean[1000];
        let end = tr.mean[2000];
        println!(
            "{preset:10} start {:+.3}  after 1000↑ {peak:+.3} (±{:.3})  after 1000↓ {end:+.3}",
            tr.mean[0], tr.std[1000]
        );
        assert!(peak > tr.mean[0] && end < peak, "staircase must rise then fall");
        println!("           wrote {path}");
    }
    println!("# device_response OK (Fig. 3B data regenerated)");
}
