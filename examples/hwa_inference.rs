//! E6 — Hardware-aware training + inference over time (paper §5).
//!
//! 1. Trains an MLP twice on a genuinely hard 16-class task: (a) plain FP,
//!    (b) hardware-aware (noisy analog forward + per-batch weight noise,
//!    perfect backward/update).
//! 2. Programs both onto PCM inference tiles (programming-noise scale 3×
//!    to model a pessimistic chip).
//! 3. Evaluates accuracy from t0 = 25 s to 10 years after programming,
//!    with and without global drift compensation.
//!
//! Expected shape (paper §5 / Joshi et al. 2020): accuracy visibly decays
//! with drift; GDC and HWA training keep the network usable.
//!
//! Run: `cargo run --release --example hwa_inference`
//! Output: results/hwa_inference.csv

use aihwsim::config::{InferenceRPUConfig, RPUConfig, WeightModifier};
use aihwsim::config::MappingParameter;
use aihwsim::coordinator::checkpoint::collect_linear_layers;
use aihwsim::coordinator::evaluator::{accuracy_over_time, mlp_from_layers};
use aihwsim::coordinator::trainer::{train_classifier, TrainConfig};
use aihwsim::data::synthetic::synthetic_images_noisy;
use aihwsim::data::Dataset;
use aihwsim::nn::sequential::{mlp, Backend};
use aihwsim::nn::Module;
use aihwsim::util::logging::CsvLogger;
use aihwsim::util::matrix::Matrix;
use aihwsim::util::rng::Rng;

type Layers = Vec<(Matrix, Vec<f32>)>;

fn train(hwa: bool, ds: &Dataset) -> (f64, Layers) {
    let mut rng = Rng::new(7);
    let (cfg, backend) = if hwa {
        (RPUConfig::hwa_training(WeightModifier::AddNormal { std: 0.03 }), Backend::Analog)
    } else {
        (RPUConfig::perfect(), Backend::FloatingPoint)
    };
    let mut model = mlp(&[256, 32, 16], backend, &cfg, &mut rng);
    let tc =
        TrainConfig { epochs: 16, batch_size: 32, lr: 0.1, seed: 42, log_every: 0, csv_path: None };
    let rep = train_classifier(&mut model, ds, ds, &tc);
    let layers = collect_linear_layers(&mut model);
    (rep.final_test_acc(), layers)
}

fn main() {
    std::fs::create_dir_all("results").unwrap();
    let mut rng = Rng::new(42);
    // hard task: 16 classes, heavy pixel noise → accuracy has headroom
    let ds = synthetic_images_noisy(800, 16, 16, 1, 0.9, &mut rng);

    let (acc_fp, layers_fp) = train(false, &ds);
    let (acc_hwa, layers_hwa) = train(true, &ds);
    println!("digital accuracy:  FP-trained {acc_fp:.3}   HWA-trained {acc_hwa:.3}");
    assert!(acc_fp > 0.8 && acc_hwa > 0.8, "both trainings must converge");

    let times = [25.0f32, 3.6e3, 8.64e4, 2.6e6, 3.15e7, 3.15e8];
    let mut csv = CsvLogger::create(
        "results/hwa_inference.csv",
        &["t_seconds", "fp_gdc", "fp_raw", "hwa_gdc", "hwa_raw"],
    )
    .unwrap();
    let sweep = |layers: &Layers, gdc: bool| -> Vec<(f32, f64)> {
        let mut cfg = InferenceRPUConfig::default();
        cfg.noise_model.prog_noise_scale = 3.0; // pessimistic chip
        cfg.noise_model.read_noise_scale = 2.0;
        cfg.drift_compensation = gdc;
        let mut net = mlp_from_layers(layers, &MappingParameter::unlimited(), &mut Rng::new(5));
        net.convert_to_inference(&cfg, &mut Rng::new(99));
        accuracy_over_time(&mut net, &ds, &times, 32)
    };
    let fp_gdc = sweep(&layers_fp, true);
    let fp_raw = sweep(&layers_fp, false);
    let hwa_gdc = sweep(&layers_hwa, true);
    let hwa_raw = sweep(&layers_hwa, false);
    println!("{:>12} {:>8} {:>8} {:>8} {:>8}", "t (s)", "FP+GDC", "FP", "HWA+GDC", "HWA");
    for i in 0..times.len() {
        println!(
            "{:>12.0} {:>8.3} {:>8.3} {:>8.3} {:>8.3}",
            times[i], fp_gdc[i].1, fp_raw[i].1, hwa_gdc[i].1, hwa_raw[i].1
        );
        csv.row(&[times[i] as f64, fp_gdc[i].1, fp_raw[i].1, hwa_gdc[i].1, hwa_raw[i].1]).unwrap();
    }
    csv.flush().unwrap();

    // the §5 shape: programming costs a little accuracy, drift costs more
    let t0 = fp_gdc[0].1;
    let end = fp_gdc.last().unwrap().1;
    println!("# FP+GDC: digital {acc_fp:.3} -> programmed {t0:.3} -> 10y {end:.3}");
    assert!(t0 < acc_fp + 0.01, "programming noise must not improve accuracy");
    assert!(end < t0, "drift must degrade accuracy over 10 years: {t0:.3} -> {end:.3}");
    assert!(end > 0.6, "GDC keeps the network usable at 10y, got {end:.3}");
    println!("# wrote results/hwa_inference.csv");
    println!("# hwa_inference OK (§5 experiment regenerated)");
}
