"""AOT pipeline: lower the L2 model functions to HLO *text* artifacts that
the Rust runtime loads via the PJRT C API.

HLO text (NOT `.serialize()` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
that the published xla crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/gen_hlo.py).

Artifacts (written to --out, default ../artifacts):
  hwa_train_step.hlo.txt   (params..., x, onehot, seed, lr) -> (params', loss)
  fp_train_step.hlo.txt    (params..., x, onehot, lr)       -> (params', loss)
  analog_infer.hlo.txt     (params..., x, seed)             -> (logp,)
  analog_mvm.hlo.txt       (x, w, nout, nw)                 -> (y,)  kernel-only
  manifest.json            shapes/dtypes/argument order of each artifact

Run once at build time: `make artifacts`. Nothing here executes at request
time.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.analog_mvm import analog_mvm

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape):
    return {"shape": list(shape), "dtype": "f32"}


def param_specs():
    out = []
    for i in range(len(model.LAYER_SIZES) - 1):
        out.append(spec((model.LAYER_SIZES[i], model.LAYER_SIZES[i + 1])))
        out.append(spec((model.LAYER_SIZES[i + 1],)))
    return out


def build_artifacts(out_dir):
    os.makedirs(out_dir, exist_ok=True)
    b = model.BATCH
    nin, nout = model.LAYER_SIZES[0], model.LAYER_SIZES[-1]
    pshapes = [jax.ShapeDtypeStruct(tuple(s["shape"]), F32) for s in param_specs()]
    x = jax.ShapeDtypeStruct((b, nin), F32)
    onehot = jax.ShapeDtypeStruct((b, nout), F32)
    seed = jax.ShapeDtypeStruct((), I32)
    lr = jax.ShapeDtypeStruct((), F32)

    manifest = {"layer_sizes": list(model.LAYER_SIZES), "batch": b, "artifacts": {}}

    def emit(name, fn, *args, arg_names, num_outputs):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "args": arg_names,
            "num_outputs": num_outputs,
        }
        print(f"wrote {path} ({len(text) / 1e6:.2f} MB)")

    nparams = len(pshapes)
    pnames = []
    for i in range(nparams // 2):
        pnames += [f"w{i + 1}", f"b{i + 1}"]

    def hwa_step(*args):
        params = list(args[:nparams])
        x_, onehot_, seed_, lr_ = args[nparams:]
        return model.hwa_train_step(params, x_, onehot_, seed_, lr_)

    emit(
        "hwa_train_step",
        hwa_step,
        *pshapes,
        x,
        onehot,
        seed,
        lr,
        arg_names=pnames + ["x", "onehot", "seed", "lr"],
        num_outputs=nparams + 1,
    )

    def fp_step(*args):
        params = list(args[:nparams])
        x_, onehot_, lr_ = args[nparams:]
        return model.fp_train_step(params, x_, onehot_, lr_)

    emit(
        "fp_train_step",
        fp_step,
        *pshapes,
        x,
        onehot,
        lr,
        arg_names=pnames + ["x", "onehot", "lr"],
        num_outputs=nparams + 1,
    )

    def infer(*args):
        params = list(args[:nparams])
        x_, seed_ = args[nparams:]
        return (model.analog_infer(params, x_, seed_),)

    emit(
        "analog_infer",
        infer,
        *pshapes,
        x,
        seed,
        arg_names=pnames + ["x", "seed"],
        num_outputs=1,
    )

    # Kernel-only artifact: one fused analog MVM (runtime smoke test + L1
    # bench target).
    k, n = 256, 128
    emit(
        "analog_mvm",
        lambda x_, w_, no_, nw_: (analog_mvm(x_, w_, no_, nw_),),
        jax.ShapeDtypeStruct((b, k), F32),
        jax.ShapeDtypeStruct((k, n), F32),
        jax.ShapeDtypeStruct((b, n), F32),
        jax.ShapeDtypeStruct((b, n), F32),
        arg_names=["x", "w", "noise_out", "noise_w"],
        num_outputs=1,
    )
    manifest["artifacts"]["analog_mvm"]["shapes"] = {
        "x": [b, k],
        "w": [k, n],
        "noise_out": [b, n],
        "noise_w": [b, n],
    }

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {out_dir}/manifest.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    build_artifacts(args.out)


if __name__ == "__main__":
    main()
