"""L1 Pallas kernels."""
