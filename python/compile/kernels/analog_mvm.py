"""L1 Pallas kernel: the fused analog matrix-vector multiply of Eq. (1).

The paper's RPUCUDA core fuses DAC discretization, the MVM, weight/output
noise injection, and ADC clipping into single CUDA kernels. On TPU the same
fusion is expressed as one Pallas kernel tiled for VMEM/the MXU (see
DESIGN.md §Hardware-Adaptation):

  * the (batch, in) x (in, out) matmul is tiled into (BB, K) x (K, BN)
    VMEM blocks feeding the MXU;
  * the DAC quantize/clip of the inputs is fused into the x-block load;
  * weight read noise is *output-referred*: sum_j sigma_w xi_ij x_j is
    N(0, sigma_w^2 ||x||^2) per output, so the kernel adds
    sigma_w * ||x_row|| * xi with xi ~ N(0,1) supplied as an input tensor
    (distribution-exact, same trick as the Rust core and RPUCUDA);
  * output noise and ADC clip/quantize are fused into the store.

Noise tensors are sampled in L2 (jax.random, threaded PRNG key) and passed
in, keeping the kernel deterministic and replayable.

Pallas runs with interpret=True: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and correctness is what we validate here (see ref.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default IO parameters (mirror rust config::io defaults; resolutions are
# step sizes as a fraction of the full range, see IOParameters docs).
DEFAULT_IO = dict(
    inp_bound=1.0,
    inp_res=1.0 / 126.0,  # 7-bit DAC
    out_bound=12.0,
    out_res=1.0 / 510.0,  # 9-bit ADC
    out_noise=0.06,
    w_noise=0.0,
)


def _quantize(v, step):
    if step <= 0.0:
        return v
    return jnp.round(v / step) * step


def _analog_mvm_kernel(
    x_ref, w_ref, nout_ref, nw_ref, scale_ref, o_ref, *, io
):
    """One (BB, BN) output block: fused DAC -> MXU matmul -> noise -> ADC.

    scale_ref holds the per-row noise-management scale (absmax), computed
    in L2 so every grid column sees the same scale.
    """
    x = x_ref[...]  # (BB, K)
    scale = scale_ref[...]  # (BB, 1)
    # --- DAC: scale into [-inp_bound, inp_bound], clip, quantize ---
    inp_step = io["inp_res"] * 2.0 * io["inp_bound"]
    xs = x / scale
    xs = jnp.clip(xs, -io["inp_bound"], io["inp_bound"])
    xq = _quantize(xs, inp_step)
    # --- analog MVM on the MXU ---
    acc = jnp.dot(xq, w_ref[...], preferred_element_type=jnp.float32)
    # --- weight read noise (output-referred, distribution-exact) ---
    if io["w_noise"] > 0.0:
        xnorm = jnp.sqrt(jnp.sum(xq * xq, axis=-1, keepdims=True))
        acc = acc + io["w_noise"] * xnorm * nw_ref[...]
    # --- output noise ---
    if io["out_noise"] > 0.0:
        acc = acc + io["out_noise"] * nout_ref[...]
    # --- ADC: clip, quantize, undo input scaling ---
    out_step = io["out_res"] * 2.0 * io["out_bound"]
    acc = jnp.clip(acc, -io["out_bound"], io["out_bound"])
    acc = _quantize(acc, out_step)
    o_ref[...] = acc * scale


def analog_mvm(x, w, noise_out, noise_w, io=None, block_b=128, block_n=128):
    """Fused analog MVM: y = f_adc(f_dac(x) @ w + noise) (Eq. 1).

    Args:
      x: (B, K) inputs.
      w: (K, N) weights in normalized units.
      noise_out: (B, N) standard normals (output noise).
      noise_w: (B, N) standard normals (weight read noise).
      io: dict of IO parameters (DEFAULT_IO fields).

    Returns (B, N) outputs.
    """
    io = {**DEFAULT_IO, **(io or {})}
    b, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert noise_out.shape == (b, n)
    assert noise_w.shape == (b, n)
    # noise management: per-row absmax input scale (computed outside the
    # kernel so all column blocks agree)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-12)

    bb = min(block_b, b)
    bn = min(block_n, n)
    grid = (pl.cdiv(b, bb), pl.cdiv(n, bn))
    kernel = functools.partial(_analog_mvm_kernel, io=io)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=True,
    )(x, w, noise_out, noise_w, scale)
