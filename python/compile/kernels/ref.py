"""Pure-jnp oracle for the analog MVM kernel (the correctness reference).

Implements exactly the pipeline of Eq. (1) that analog_mvm.py fuses into a
Pallas kernel, in straight jax.numpy. pytest asserts allclose between the
two across shapes and IO-parameter sweeps.
"""

import jax.numpy as jnp

from .analog_mvm import DEFAULT_IO


def quantize_ref(v, step):
    if step <= 0.0:
        return v
    return jnp.round(v / step) * step


def analog_mvm_ref(x, w, noise_out, noise_w, io=None):
    """Reference analog MVM: same math as the Pallas kernel, no tiling."""
    io = {**DEFAULT_IO, **(io or {})}
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-12)
    inp_step = io["inp_res"] * 2.0 * io["inp_bound"]
    xs = jnp.clip(x / scale, -io["inp_bound"], io["inp_bound"])
    xq = quantize_ref(xs, inp_step)
    acc = xq @ w
    if io["w_noise"] > 0.0:
        xnorm = jnp.sqrt(jnp.sum(xq * xq, axis=-1, keepdims=True))
        acc = acc + io["w_noise"] * xnorm * noise_w
    if io["out_noise"] > 0.0:
        acc = acc + io["out_noise"] * noise_out
    out_step = io["out_res"] * 2.0 * io["out_bound"]
    acc = jnp.clip(acc, -io["out_bound"], io["out_bound"])
    acc = quantize_ref(acc, out_step)
    return acc * scale
