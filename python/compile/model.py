"""L2 JAX model: hardware-aware training and analog inference for a fixed
MLP (784-256-128-10), built on the L1 Pallas kernel.

Hardware-aware training (paper section 5): the forward pass runs through the
*noisy analog* MVM (Pallas kernel), while backward and update are "perfect"
(exact FP gradients) — implemented with jax.custom_vjp straight-through
layers. The whole train step (fwd + bwd + SGD update) lowers to a single
HLO module that the Rust runtime executes; Python never runs at request
time.

Weights follow the (in, out) convention here; the Rust coordinator stores
(out, in) row-major and transposes when marshaling (see runtime/).
"""

import jax
import jax.numpy as jnp

from .kernels.analog_mvm import DEFAULT_IO, analog_mvm
from .kernels.ref import analog_mvm_ref

# Fixed architecture for the AOT artifacts.
LAYER_SIZES = (784, 256, 128, 10)
BATCH = 64

# HWA forward IO: PCM-inference-like noise (mirrors
# IOParameters::inference_default in rust).
HWA_IO = {**DEFAULT_IO, "out_noise": 0.04, "w_noise": 0.0175}


def init_params(key):
    """Kaiming-uniform init, matching the rust AnalogLinear init."""
    params = []
    for i in range(len(LAYER_SIZES) - 1):
        key, sub = jax.random.split(key)
        fan_in = LAYER_SIZES[i]
        bound = 1.0 / float(fan_in) ** 0.5
        w = jax.random.uniform(
            sub, (LAYER_SIZES[i], LAYER_SIZES[i + 1]), jnp.float32, -bound, bound
        )
        b = jnp.zeros((LAYER_SIZES[i + 1],), jnp.float32)
        params += [w, b]
    return params


@jax.custom_vjp
def hwa_linear(x, w, noise_out, noise_w):
    """Analog-noisy forward, exact ("perfect") backward — the HWA layer."""
    return analog_mvm(x, w, noise_out, noise_w, io=HWA_IO)


def _hwa_fwd(x, w, noise_out, noise_w):
    y = analog_mvm(x, w, noise_out, noise_w, io=HWA_IO)
    return y, (x, w)


def _hwa_bwd(res, g):
    x, w = res
    return g @ w.T, x.T @ g, None, None


hwa_linear.defvjp(_hwa_fwd, _hwa_bwd)


def _split_params(params):
    assert len(params) == 2 * (len(LAYER_SIZES) - 1)
    return [(params[2 * i], params[2 * i + 1]) for i in range(len(LAYER_SIZES) - 1)]


def hwa_forward(params, x, seed):
    """Analog forward through all layers (tanh hidden units, log-softmax
    head) with fresh noise per layer derived from `seed`."""
    key = jax.random.PRNGKey(seed)
    h = x
    layers = _split_params(params)
    for li, (w, b) in enumerate(layers):
        key, k1, k2 = jax.random.split(key, 3)
        nout = jax.random.normal(k1, (h.shape[0], w.shape[1]), jnp.float32)
        nw = jax.random.normal(k2, (h.shape[0], w.shape[1]), jnp.float32)
        h = hwa_linear(h, w, nout, nw) + b
        if li + 1 < len(layers):
            h = jnp.tanh(h)
    return jax.nn.log_softmax(h, axis=-1)


def fp_forward(params, x):
    """Exact FP forward (baseline)."""
    h = x
    layers = _split_params(params)
    for li, (w, b) in enumerate(layers):
        h = h @ w + b
        if li + 1 < len(layers):
            h = jnp.tanh(h)
    return jax.nn.log_softmax(h, axis=-1)


def _nll(logp, onehot):
    return -jnp.mean(jnp.sum(logp * onehot, axis=-1))


def hwa_train_step(params, x, onehot, seed, lr):
    """One hardware-aware SGD step. Returns (new_params..., loss)."""

    def loss_fn(ps):
        return _nll(hwa_forward(ps, x, seed), onehot)

    loss, grads = jax.value_and_grad(loss_fn)(list(params))
    new_params = [p - lr * g for p, g in zip(params, grads)]
    return tuple(new_params) + (loss,)


def fp_train_step(params, x, onehot, lr):
    """One exact FP SGD step (the baseline of footnote 3)."""

    def loss_fn(ps):
        return _nll(fp_forward(ps, x), onehot)

    loss, grads = jax.value_and_grad(loss_fn)(list(params))
    new_params = [p - lr * g for p, g in zip(params, grads)]
    return tuple(new_params) + (loss,)


def analog_infer(params, x, seed):
    """Noisy analog inference forward (drifted weights are computed by the
    Rust inference tile and passed in as `params`). Returns log-probs."""
    return hwa_forward(params, x, seed)


def reference_forward(params, x, seed):
    """Oracle forward using ref.py (used in pytest only)."""
    key = jax.random.PRNGKey(seed)
    h = x
    layers = _split_params(params)
    for li, (w, b) in enumerate(layers):
        key, k1, k2 = jax.random.split(key, 3)
        nout = jax.random.normal(k1, (h.shape[0], w.shape[1]), jnp.float32)
        nw = jax.random.normal(k2, (h.shape[0], w.shape[1]), jnp.float32)
        h = analog_mvm_ref(h, w, nout, nw, io=HWA_IO) + b
        if li + 1 < len(layers):
            h = jnp.tanh(h)
    return jax.nn.log_softmax(h, axis=-1)
