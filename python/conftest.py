import os
import sys

# make `compile` importable regardless of where pytest is invoked from
sys.path.insert(0, os.path.dirname(__file__))
