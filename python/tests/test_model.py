"""L2 model tests: shapes, HWA semantics (noisy fwd / exact bwd), training
step progress, and kernel-vs-oracle consistency at the model level."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def small_batch(b=8, seed=0):
    key = jax.random.PRNGKey(seed)
    kx, kl = jax.random.split(key)
    x = jax.random.uniform(kx, (b, model.LAYER_SIZES[0]), jnp.float32)
    labels = jax.random.randint(kl, (b,), 0, model.LAYER_SIZES[-1])
    onehot = jax.nn.one_hot(labels, model.LAYER_SIZES[-1], dtype=jnp.float32)
    return x, onehot


class TestForward:
    def test_shapes_and_normalization(self):
        params = model.init_params(jax.random.PRNGKey(0))
        x, _ = small_batch()
        logp = model.hwa_forward(params, x, 7)
        assert logp.shape == (8, model.LAYER_SIZES[-1])
        p = np.exp(np.asarray(logp)).sum(axis=-1)
        np.testing.assert_allclose(p, 1.0, atol=1e-4)

    def test_kernel_matches_reference_forward(self):
        params = model.init_params(jax.random.PRNGKey(1))
        x, _ = small_batch(b=4, seed=1)
        a = model.hwa_forward(params, x, 3)
        b = model.reference_forward(params, x, 3)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)

    def test_noise_varies_with_seed(self):
        params = model.init_params(jax.random.PRNGKey(2))
        x, _ = small_batch(b=4, seed=2)
        a = model.hwa_forward(params, x, 1)
        b = model.hwa_forward(params, x, 2)
        assert not np.allclose(np.asarray(a), np.asarray(b))

    def test_fp_forward_deterministic(self):
        params = model.init_params(jax.random.PRNGKey(3))
        x, _ = small_batch(b=4, seed=3)
        a = model.fp_forward(params, x)
        b = model.fp_forward(params, x)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestHWATraining:
    def test_gradients_are_clean(self):
        """HWA backward must be the *exact* FP gradient of the clean path
        (straight-through custom_vjp), not a gradient of the noise."""
        params = model.init_params(jax.random.PRNGKey(4))
        x, onehot = small_batch(b=4, seed=4)

        def loss_hwa(ps):
            logp = model.hwa_forward(ps, x, 5)
            return -jnp.mean(jnp.sum(logp * onehot, axis=-1))

        g = jax.grad(loss_hwa)(params)
        # gradient must be finite and nonzero
        for gi in g:
            arr = np.asarray(gi)
            assert np.all(np.isfinite(arr))
        assert any(np.abs(np.asarray(gi)).max() > 0 for gi in g)

    def test_train_step_reduces_loss(self):
        params = model.init_params(jax.random.PRNGKey(5))
        x, onehot = small_batch(b=16, seed=5)
        step = jax.jit(model.hwa_train_step)
        losses = []
        for i in range(30):
            out = step(params, x, onehot, i, 0.2)
            params = list(out[:-1])
            losses.append(float(out[-1]))
        assert losses[-1] < losses[0] * 0.9, losses[:3] + losses[-3:]

    def test_fp_train_step_reduces_loss(self):
        params = model.init_params(jax.random.PRNGKey(6))
        x, onehot = small_batch(b=16, seed=6)
        step = jax.jit(model.fp_train_step)
        losses = []
        for _ in range(30):
            out = step(params, x, onehot, 0.2)
            params = list(out[:-1])
            losses.append(float(out[-1]))
        assert losses[-1] < losses[0] * 0.8
