"""Kernel-vs-reference correctness: the CORE L1 signal.

The Pallas kernel (interpret=True) must match the pure-jnp oracle bit-for-
bit-ish (fp32 tolerance) across shapes, tilings, and IO-parameter sweeps.
Hypothesis drives the shape/parameter space.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.analog_mvm import DEFAULT_IO, analog_mvm
from compile.kernels.ref import analog_mvm_ref


def rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


def run_both(b, k, n, io, seed=0, block_b=128, block_n=128):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = rand(ks[0], b, k)
    w = 0.3 * rand(ks[1], k, n)
    nout = rand(ks[2], b, n)
    nw = rand(ks[3], b, n)
    y_kernel = analog_mvm(x, w, nout, nw, io=io, block_b=block_b, block_n=block_n)
    y_ref = analog_mvm_ref(x, w, nout, nw, io=io)
    return np.asarray(y_kernel), np.asarray(y_ref)


class TestKernelVsRef:
    def test_default_io(self):
        yk, yr = run_both(8, 32, 16, None)
        np.testing.assert_allclose(yk, yr, rtol=1e-5, atol=1e-5)

    def test_noise_free(self):
        io = {**DEFAULT_IO, "out_noise": 0.0, "inp_res": 0.0, "out_res": 0.0}
        yk, yr = run_both(4, 16, 8, io)
        np.testing.assert_allclose(yk, yr, rtol=1e-5, atol=1e-6)

    def test_weight_noise_path(self):
        io = {**DEFAULT_IO, "w_noise": 0.05}
        yk, yr = run_both(4, 64, 32, io)
        np.testing.assert_allclose(yk, yr, rtol=1e-5, atol=1e-5)

    def test_multi_block_grid(self):
        # force a multi-tile grid: block smaller than the matrix
        yk, yr = run_both(96, 48, 80, None, block_b=32, block_n=32)
        np.testing.assert_allclose(yk, yr, rtol=1e-5, atol=1e-5)

    def test_ragged_blocks(self):
        # dims not divisible by the block size
        yk, yr = run_both(33, 20, 17, None, block_b=16, block_n=8)
        np.testing.assert_allclose(yk, yr, rtol=1e-5, atol=1e-5)

    @given(
        b=st.integers(1, 48),
        k=st.integers(1, 96),
        n=st.integers(1, 48),
        out_noise=st.sampled_from([0.0, 0.02, 0.1]),
        w_noise=st.sampled_from([0.0, 0.02]),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=25, deadline=None)
    def test_hypothesis_sweep(self, b, k, n, out_noise, w_noise, seed):
        io = {**DEFAULT_IO, "out_noise": out_noise, "w_noise": w_noise}
        yk, yr = run_both(b, k, n, io, seed=seed, block_b=16, block_n=16)
        np.testing.assert_allclose(yk, yr, rtol=1e-4, atol=1e-4)

    @given(
        inp_bits=st.sampled_from([0, 4, 7, 8]),
        out_bits=st.sampled_from([0, 6, 9]),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=15, deadline=None)
    def test_resolution_sweep(self, inp_bits, out_bits, seed):
        io = {
            **DEFAULT_IO,
            "inp_res": 0.0 if inp_bits == 0 else 1.0 / (2**inp_bits - 2),
            "out_res": 0.0 if out_bits == 0 else 1.0 / (2**out_bits - 2),
        }
        yk, yr = run_both(5, 24, 12, io, seed=seed)
        np.testing.assert_allclose(yk, yr, rtol=1e-4, atol=1e-4)


class TestKernelSemantics:
    def test_quantization_actually_quantizes(self):
        io = {**DEFAULT_IO, "out_noise": 0.0, "w_noise": 0.0, "inp_res": 0.25, "out_res": 0.0}
        key = jax.random.PRNGKey(1)
        x = jax.random.uniform(key, (2, 8), jnp.float32, -1.0, 1.0)
        w = jnp.eye(8, dtype=jnp.float32)
        z = jnp.zeros((2, 8), jnp.float32)
        y = analog_mvm(x, w, z, z, io=io)
        # after absmax scaling + 0.5-step quantization, outputs/scale must
        # sit on the quantization grid
        scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        grid = np.asarray((y / scale) / 0.5)
        np.testing.assert_allclose(grid, np.round(grid), atol=1e-5)

    def test_output_noise_statistics(self):
        io = {**DEFAULT_IO, "out_noise": 0.1, "inp_res": 0.0, "out_res": 0.0}
        b, k, n = 64, 8, 64
        x = jnp.ones((b, k), jnp.float32)
        w = jnp.zeros((k, n), jnp.float32)
        nw = jnp.zeros((b, n), jnp.float32)
        nout = jax.random.normal(jax.random.PRNGKey(2), (b, n), jnp.float32)
        y = analog_mvm(x, w, nout, nw, io=io)
        # zero weights: y = out_noise * nout * scale (scale = 1)
        np.testing.assert_allclose(np.asarray(y), 0.1 * np.asarray(nout), atol=1e-6)

    def test_clipping_at_out_bound(self):
        io = {**DEFAULT_IO, "out_noise": 0.0, "w_noise": 0.0, "out_bound": 2.0, "out_res": 0.0}
        x = jnp.ones((1, 16), jnp.float32)
        w = jnp.ones((16, 1), jnp.float32)
        z = jnp.zeros((1, 1), jnp.float32)
        y = analog_mvm(x, w, z, z, io=io)
        # raw y/scale = 16, clipped at 2 → y = 2·scale = 2
        assert float(y[0, 0]) == pytest.approx(2.0, abs=1e-5)

    def test_linear_in_scale(self):
        # absmax noise management: doubling x doubles y exactly (quiet)
        io = {**DEFAULT_IO, "out_noise": 0.0, "w_noise": 0.0}
        key = jax.random.PRNGKey(3)
        x = jax.random.normal(key, (3, 16), jnp.float32)
        w = 0.2 * jax.random.normal(jax.random.PRNGKey(4), (16, 8), jnp.float32)
        z = jnp.zeros((3, 8), jnp.float32)
        y1 = analog_mvm(x, w, z, z, io=io)
        y2 = analog_mvm(2.0 * x, w, z, z, io=io)
        np.testing.assert_allclose(np.asarray(y2), 2.0 * np.asarray(y1), rtol=1e-5, atol=1e-5)
