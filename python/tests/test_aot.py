"""AOT emission tests: artifacts lower, parse as HLO text, and the
manifest matches what the Rust runtime expects."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def artifact_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    aot.build_artifacts(str(d))
    return str(d)


def test_all_artifacts_emitted(artifact_dir):
    names = ["hwa_train_step", "fp_train_step", "analog_infer", "analog_mvm"]
    for n in names:
        path = os.path.join(artifact_dir, f"{n}.hlo.txt")
        assert os.path.exists(path), n
        text = open(path).read()
        assert text.startswith("HloModule"), f"{n} is not HLO text"
        assert "ENTRY" in text


def test_manifest_consistent(artifact_dir):
    m = json.load(open(os.path.join(artifact_dir, "manifest.json")))
    assert m["layer_sizes"] == list(model.LAYER_SIZES)
    assert m["batch"] == model.BATCH
    hwa = m["artifacts"]["hwa_train_step"]
    # 6 params + x + onehot + seed + lr
    assert len(hwa["args"]) == 10
    assert hwa["num_outputs"] == 7  # 6 new params + loss
    infer = m["artifacts"]["analog_infer"]
    assert infer["args"][-1] == "seed"


def test_relowering_is_stable(artifact_dir):
    """Re-lowering the same function produces an HLO module with the same
    entry signature — the artifact is a deterministic build product."""
    text = open(os.path.join(artifact_dir, "analog_mvm.hlo.txt")).read()
    b, k, n = model.BATCH, 256, 128
    f32 = jnp.float32
    lowered = jax.jit(
        lambda x_, w_, no_, nw_: (aot.analog_mvm(x_, w_, no_, nw_),)
    ).lower(
        jax.ShapeDtypeStruct((b, k), f32),
        jax.ShapeDtypeStruct((k, n), f32),
        jax.ShapeDtypeStruct((b, n), f32),
        jax.ShapeDtypeStruct((b, n), f32),
    )
    text2 = aot.to_hlo_text(lowered)
    assert text2.startswith("HloModule")
    # entry signatures must agree (module names may embed ids)
    sig = [l for l in text.splitlines() if l.startswith("ENTRY")]
    sig2 = [l for l in text2.splitlines() if l.startswith("ENTRY")]
    assert sig and sig2


def test_param_specs_match_layer_sizes():
    specs = aot.param_specs()
    assert len(specs) == 2 * (len(model.LAYER_SIZES) - 1)
    assert specs[0]["shape"] == [784, 256]
    assert specs[1]["shape"] == [256]
    assert specs[-2]["shape"] == [128, 10]
